"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness asserts; decode-vs-forward consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_bundle
from repro.optim import adamw_init
from repro.training import TrainHyper, make_train_step


def _batch(bundle, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, bundle.cfg.vocab),
        "labels": jax.random.randint(k, (B, S), 0, bundle.cfg.vocab),
    }
    if bundle.kind == "audio":
        batch["frames"] = jax.random.normal(
            k, (B, bundle.cfg.n_audio_ctx, bundle.cfg.d_model), jnp.float32)
    if bundle.kind == "vlm":
        batch["vision"] = jax.random.normal(
            k, (B, bundle.cfg.vision_tokens, bundle.cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    bundle = get_bundle(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = _batch(bundle)
    logits, aux = bundle.forward(params, batch)
    S_out = 16 + (bundle.cfg.vision_tokens if bundle.kind == "vlm" else 0)
    assert logits.shape == (2, S_out, bundle.cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    bundle = get_bundle(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = make_train_step(bundle.forward, TrainHyper())
    params, opt, metrics = jax.jit(step)(params, opt, _batch(bundle))
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["qwen3-4b", "qwen1.5-32b", "olmoe-1b-7b",
                                  "mamba2-370m", "whisper-medium",
                                  "recurrentgemma-9b"])
def test_decode_matches_forward(arch):
    """prefill(t[:15]) + decode(t[15]) logits == forward(t)[-1]."""
    bundle = get_bundle(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = _batch(bundle)
    toks = batch["tokens"]
    extras = {k: v for k, v in batch.items()
              if k not in ("tokens", "labels")}
    cache = bundle.init_cache(2, 32)
    lg, cache = bundle.prefill(params, toks[:, :15], cache,
                               batch_extras=extras or None)
    lg2, cache = bundle.decode_step(params, toks[:, 15:16], cache)
    full, _ = bundle.forward(params, batch)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=3e-2, atol=3e-2)


def test_moe_microbatch_grad_accumulation():
    """microbatches=2 matches microbatches=1 loss on the same batch."""
    bundle = get_bundle("olmoe-1b-7b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = _batch(bundle, B=4)
    s1 = make_train_step(bundle.forward, TrainHyper(microbatches=1))
    s2 = make_train_step(bundle.forward, TrainHyper(microbatches=2))
    _, _, m1 = jax.jit(s1)(params, adamw_init(params), batch)
    _, _, m2 = jax.jit(s2)(params, adamw_init(params), batch)
    # microbatched loss is the mean over microbatches of per-micro losses
    assert abs(float(m1["ce"]) - float(m2["ce"])) < 5e-2


def test_int8_kv_cache_close_to_fp():
    bundle = get_bundle("qwen1.5-32b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              bundle.cfg.vocab)
    c_fp = bundle.init_cache(2, 32)
    c_q = bundle.init_cache(2, 32, kv_dtype=jnp.int8)
    lg_fp, c_fp = bundle.prefill(params, toks[:, :15], c_fp)
    lg_q, c_q = bundle.prefill(params, toks[:, :15], c_q)
    d_fp, _ = bundle.decode_step(params, toks[:, 15:16], c_fp)
    d_q, _ = bundle.decode_step(params, toks[:, 15:16], c_q)
    np.testing.assert_allclose(np.asarray(d_q), np.asarray(d_fp),
                               rtol=5e-2, atol=5e-2)


def test_param_counts_plausible():
    expect = {
        "qwen3-4b": (3.5e9, 5.5e9),
        "qwen2.5-14b": (13e9, 16e9),
        "qwen1.5-32b": (30e9, 38e9),
        "yi-9b": (8e9, 10e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "mamba2-370m": (0.3e9, 0.55e9),
        "whisper-medium": (0.6e9, 0.95e9),
        "recurrentgemma-9b": (8.5e9, 12e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_bundle(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_much_smaller():
    b = get_bundle("olmoe-1b-7b")
    assert b.active_param_count() < 0.3 * b.param_count()
