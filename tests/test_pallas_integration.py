"""The Pallas flash kernel as a drop-in attention impl inside models
(interpret mode on CPU; compiled Mosaic on real TPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import TransformerConfig, transformer


def test_model_forward_pallas_vs_xla():
    kw = dict(name="p", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
              d_ff=128, vocab=128, dtype=jnp.float32, remat=False)
    cfg_x = TransformerConfig(attn_impl="xla", **kw)
    cfg_p = TransformerConfig(attn_impl="pallas", **kw)
    params = transformer.init_params(cfg_x, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    lx, _ = transformer.forward(cfg_x, params, toks)
    lp, _ = transformer.forward(cfg_p, params, toks)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                               rtol=2e-3, atol=2e-3)


def test_model_forward_pallas_windowed():
    kw = dict(name="p", n_layers=1, d_model=32, n_heads=4, n_kv_heads=1,
              d_ff=64, vocab=64, dtype=jnp.float32, remat=False, window=8)
    cfg_x = TransformerConfig(attn_impl="xla", **kw)
    cfg_p = TransformerConfig(attn_impl="pallas", **kw)
    params = transformer.init_params(cfg_x, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, 64)
    lx, _ = transformer.forward(cfg_x, params, toks)
    lp, _ = transformer.forward(cfg_p, params, toks)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                               rtol=2e-3, atol=2e-3)
