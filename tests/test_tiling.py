"""Tile search (paper §II-B): capacity constraints + bandwidth optimality."""
import math

import pytest

from repro.core import (TEU_BUFFER, BufferSpec, conv2d_op, matmul_op,
                        search_tiles, schedule_for, tile_fits, traffic)


def test_search_respects_buffers():
    op = matmul_op(512, 512, 512)
    s = search_tiles(op, TEU_BUFFER)
    assert s.input_bytes <= TEU_BUFFER.input_bytes
    assert s.psum_bytes <= TEU_BUFFER.psum_bytes


def test_search_minimizes_bytes_per_mac():
    """No power-of-two tile that fits beats the chosen one."""
    op = matmul_op(256, 256, 256)
    best = search_tiles(op, TEU_BUFFER)
    from repro.core.ndrange import enumerate_tiles
    for tile in enumerate_tiles(op):
        if tile_fits(op, tile, TEU_BUFFER):
            assert op.tile_bytes_per_mac(tile) >= best.bytes_per_mac - 1e-12


def test_square_psum_tile_is_optimal_shape():
    """For matmul, (t_i + t_j)/(t_i t_j) is minimized by square tiles."""
    op = matmul_op(1024, 1024, 1024)
    s = search_tiles(op, TEU_BUFFER)
    assert s.tile["i"] == s.tile["j"]


def test_infeasible_raises():
    op = matmul_op(8, 8, 8)
    with pytest.raises(ValueError):
        search_tiles(op, BufferSpec(input_bytes=4, psum_bytes=1))


def test_traffic_sharing_reduces_fetches():
    op = matmul_op(256, 256, 256)
    s = search_tiles(op, TEU_BUFFER)
    t0 = traffic(op, s.tile)
    t1 = traffic(op, s.tile, shared_axes=("i", "j"))
    assert t1.input_fetch_bytes < t0.input_fetch_bytes
    assert t1.output_write_bytes == t0.output_write_bytes


def test_output_written_once():
    """PSum-stationary scheduling: one external write per output element."""
    op = conv2d_op(16, 8, 12, 12, 3, 3)
    s = search_tiles(op, TEU_BUFFER)
    t = traffic(op, s.tile)
    assert t.output_write_bytes == 16 * 12 * 12 * 2


def test_conv_search_fits_and_nontrivial():
    op = conv2d_op(64, 32, 26, 26, 3, 3)
    s = search_tiles(op, TEU_BUFFER)
    assert s.macs > 32 * 32          # bigger than a trivial tile
    assert s.input_bytes <= TEU_BUFFER.input_bytes
