"""Tile search (paper §II-B): capacity constraints + bandwidth optimality."""
import math

import pytest

from repro.core import (TEU_BUFFER, BufferSpec, conv2d_op, matmul_op,
                        search_tiles, schedule_for, tile_fits, traffic)


def test_search_respects_buffers():
    op = matmul_op(512, 512, 512)
    s = search_tiles(op, TEU_BUFFER)
    assert s.input_bytes <= TEU_BUFFER.input_bytes
    assert s.psum_bytes <= TEU_BUFFER.psum_bytes


def test_search_minimizes_bytes_per_mac():
    """No power-of-two tile that fits beats the chosen one."""
    op = matmul_op(256, 256, 256)
    best = search_tiles(op, TEU_BUFFER)
    from repro.core.ndrange import enumerate_tiles
    for tile in enumerate_tiles(op):
        if tile_fits(op, tile, TEU_BUFFER):
            assert op.tile_bytes_per_mac(tile) >= best.bytes_per_mac - 1e-12


def test_square_psum_tile_is_optimal_shape():
    """For matmul, (t_i + t_j)/(t_i t_j) is minimized by square tiles."""
    op = matmul_op(1024, 1024, 1024)
    s = search_tiles(op, TEU_BUFFER)
    assert s.tile["i"] == s.tile["j"]


def test_infeasible_raises():
    op = matmul_op(8, 8, 8)
    with pytest.raises(ValueError):
        search_tiles(op, BufferSpec(input_bytes=4, psum_bytes=1))


def test_traffic_sharing_reduces_fetches():
    op = matmul_op(256, 256, 256)
    s = search_tiles(op, TEU_BUFFER)
    t0 = traffic(op, s.tile)
    t1 = traffic(op, s.tile, shared_axes=("i", "j"))
    assert t1.input_fetch_bytes < t0.input_fetch_bytes
    assert t1.output_write_bytes == t0.output_write_bytes


def test_output_written_once():
    """PSum-stationary scheduling: one external write per output element."""
    op = conv2d_op(16, 8, 12, 12, 3, 3)
    s = search_tiles(op, TEU_BUFFER)
    t = traffic(op, s.tile)
    assert t.output_write_bytes == 16 * 12 * 12 * 2


def test_traffic_matmul_hand_computed():
    """Pin exact traffic() byte counts (guards the fetch arithmetic)."""
    op = matmul_op(64, 64, 64)                    # bf16: 2 B/elem
    tile = {"i": 16, "j": 16, "k": 32}
    t = traffic(op, tile)
    # A tile: 16x32 elems, B tile: 32x16 elems; 4*4*2 = 32 tiles, no sharing
    assert t.input_fetch_bytes == (16 * 32 + 32 * 16) * 2 * 32 == 65536
    assert t.output_write_bytes == 64 * 64 * 2 == 8192
    assert t.total_macs == 64 ** 3
    # sharing along j: A (invariant to j) fetched once per 4-tile j-group
    tj = traffic(op, tile, shared_axes=("j",))
    assert tj.input_fetch_bytes == 1024 * (32 // 4) + 1024 * 32 == 40960


def test_traffic_conv_hand_computed():
    op = conv2d_op(8, 4, 8, 8, 3, 3)
    tile = {"co": 4, "y": 4, "x": 4, "ci": 4, "m": 3, "n": 3}
    t = traffic(op, tile)
    # I tile: 4 ci x (4+3-1) x (4+3-1) = 144 elems; K tile: 4*4*3*3 = 144;
    # grid = 2*2*2 = 8 tiles
    assert t.input_fetch_bytes == (144 + 144) * 2 * 8 == 4608
    assert t.output_write_bytes == 8 * 8 * 8 * 2 == 1024
    # I is invariant to co: shared along co it is fetched once per co-pair
    tc = traffic(op, tile, shared_axes=("co",))
    assert tc.input_fetch_bytes == 288 * (8 // 2) + 288 * 8 == 3456


def test_conv_search_fits_and_nontrivial():
    op = conv2d_op(64, 32, 26, 26, 3, 3)
    s = search_tiles(op, TEU_BUFFER)
    assert s.macs > 32 * 32          # bigger than a trivial tile
    assert s.input_bytes <= TEU_BUFFER.input_bytes
