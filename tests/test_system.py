"""End-to-end behaviour tests: training loop, restart, serving."""
import jax
import numpy as np

from repro.launch.serve import run as serve_run
from repro.launch.train import run as train_run


def test_train_loss_decreases(tmp_path):
    out = train_run("qwen3-4b", smoke=True, steps=15, seq_len=64,
                    global_batch=4, ckpt_dir=str(tmp_path), ckpt_every=50,
                    lr=1e-3, log_every=100)
    losses = out["losses"]
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_train_restart_resumes(tmp_path):
    train_run("mamba2-370m", smoke=True, steps=6, seq_len=32,
              global_batch=4, ckpt_dir=str(tmp_path), ckpt_every=6,
              log_every=100)
    out = train_run("mamba2-370m", smoke=True, steps=3, seq_len=32,
                    global_batch=4, ckpt_dir=str(tmp_path), ckpt_every=50,
                    log_every=100)
    # restart restored from step 6 and kept training without divergence
    assert len(out["losses"]) == 3
    assert all(np.isfinite(out["losses"]))


def test_serving_continuous_batching():
    results = serve_run("qwen3-4b", smoke=True, n_requests=5, slots=2,
                        prompt_len=8, max_new=6, max_len=32)
    assert len(results) == 5
    assert all(len(v) == 6 for v in results.values())


def test_serving_moe_arch():
    results = serve_run("olmoe-1b-7b", smoke=True, n_requests=3, slots=3,
                        prompt_len=6, max_new=4, max_len=24)
    assert len(results) == 3
