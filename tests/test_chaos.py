"""Fault-tolerant pod runtime: chaos injection, verified checkpoints,
self-healing train loop.

Covers the acceptance scenarios of the fault-tolerance PR, all
deterministic:

  * kill@N -> restart -> BIT-IDENTICAL loss trajectory vs an
    uninterrupted run;
  * corrupt@N -> CRC verification rejects the newest checkpoint and the
    restore falls back to the newest intact older step;
  * nan@N -> the in-jit finite guard skips the update (params untouched)
    and the run stays finite;
  * silence@N:host=H -> heartbeat eviction -> elastic re-mesh -> the loop
    completes over the survivors;
  * checkpoint v2 invariants: multi-host saves don't clobber, treedef
    mismatch raises with the first diverging leaf path, straggler
    detection excludes self from the median (the n=2 case).
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, CheckpointManager,
                              TreeStructureError, latest_step,
                              restore_checkpoint, save_checkpoint,
                              verified_steps, verify_checkpoint)
from repro.runtime import HeartbeatMonitor, StragglerPolicy
from repro.runtime.chaos import (KILL_EXIT_CODE, ChaosInjector, ChaosKilled,
                                 corrupt_checkpoint, parse_chaos)

ARCH = "qwen3-4b"
TRAIN_KW = dict(smoke=True, seq_len=32, global_batch=4, log_every=1000)


# ---------------------------------------------------------------------------
# chaos specs + injector
# ---------------------------------------------------------------------------

def test_parse_chaos_specs():
    sp = parse_chaos("kill@12")
    assert (sp.kind, sp.step, sp.duration) == ("kill", 12, 1)
    sp = parse_chaos("silence@3:host=2,duration=5")
    assert (sp.kind, sp.step, sp.host, sp.duration) == ("silence", 3, 2, 5)
    sp = parse_chaos("slow@4:factor=8.0")
    assert sp.factor == 8.0 and sp.host == 1       # peer by default
    sp = parse_chaos("corrupt@8:mode=truncate")
    assert sp.mode == "truncate" and sp.host == 0  # own shard by default
    assert parse_chaos("nan@5").duration == 1
    for bad in ("kill", "kill@", "boom@3", "kill@3:wat=1", "kill@3:host"):
        with pytest.raises(ValueError):
            parse_chaos(bad)


def test_injector_fault_points_deterministic():
    chaos = ChaosInjector(["nan@3:duration=2", "silence@5:host=1",
                           "slow@2:host=2,factor=4.0,duration=3"])
    assert chaos.grad_scale(2) == 1.0
    assert np.isnan(chaos.grad_scale(3)) and np.isnan(chaos.grad_scale(4))
    assert chaos.grad_scale(5) == 1.0
    assert not chaos.heartbeat_silenced(1, 4)
    assert chaos.heartbeat_silenced(1, 5)
    assert chaos.heartbeat_silenced(1, 10 ** 6)    # silence defaults forever
    assert not chaos.heartbeat_silenced(2, 5)      # wrong host
    assert chaos.step_time_factor(2, 2) == 4.0
    assert chaos.step_time_factor(2, 5) == 1.0     # duration elapsed
    assert chaos.step_time_factor(1, 2) == 1.0
    assert "nan@3" in chaos.fired


def test_injector_kill_is_system_exit_43():
    chaos = ChaosInjector(["kill@7"])
    chaos.maybe_kill(6)                            # not yet
    with pytest.raises(ChaosKilled) as ei:
        chaos.maybe_kill(7)
    assert isinstance(ei.value, SystemExit)
    assert ei.value.code == KILL_EXIT_CODE and ei.value.step == 7


# ---------------------------------------------------------------------------
# checkpoint format v2: shared dir, commit markers, CRC verify, fallback
# ---------------------------------------------------------------------------

def _tree(seed, n=3):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, n)).astype(np.float32),
            "b": rng.normal(size=(n,)).astype(np.float32)}


def test_multi_host_shards_share_one_step_dir(tmp_path):
    """Two hosts saving the same step must not clobber each other (the
    seed's per-host dir rename deleted the other host's shard); host 0's
    manifest is the commit point."""
    path = str(tmp_path)
    t0, t1 = _tree(0), _tree(1)
    save_checkpoint(path, 5, t1, host_id=1, n_hosts=2)
    assert latest_step(path) is None               # no manifest yet
    save_checkpoint(path, 5, t0, host_id=0, n_hosts=2)
    assert latest_step(path) == 5
    step_dir = os.path.join(path, "step_00000005")
    assert sorted(f for f in os.listdir(step_dir)) == [
        "commit_0.json", "commit_1.json", "manifest.json",
        "shard_0.npz", "shard_1.npz"]
    ok, why = verify_checkpoint(path, 5)
    assert ok, why
    r0 = restore_checkpoint(path, 5, t0, host_id=0)
    r1 = restore_checkpoint(path, 5, t0, host_id=1)
    np.testing.assert_array_equal(r0["w"], t0["w"])
    np.testing.assert_array_equal(r1["w"], t1["w"])


def test_verify_detects_missing_pieces(tmp_path):
    path = str(tmp_path)
    save_checkpoint(path, 1, _tree(0), n_hosts=2)  # shard 1 never arrives
    ok, why = verify_checkpoint(path, 1)
    assert not ok and "shard 1" in why
    save_checkpoint(path, 1, _tree(1), host_id=1, n_hosts=2)
    assert verify_checkpoint(path, 1)[0]
    os.remove(os.path.join(path, "step_00000001", "commit_1.json"))
    ok, why = verify_checkpoint(path, 1)
    assert not ok and "never committed" in why


@pytest.mark.parametrize("mode", ["flip", "truncate"])
def test_corrupt_newest_falls_back_to_intact(tmp_path, mode):
    """A damaged newest checkpoint costs one interval, not the run: the
    manager's restore walks back to the newest step that passes CRC."""
    path = str(tmp_path)
    t1, t2 = _tree(1), _tree(2)
    save_checkpoint(path, 10, t1)
    save_checkpoint(path, 20, t2)
    corrupt_checkpoint(path, 20, mode=mode)
    assert verified_steps(path) == [10]
    mgr = CheckpointManager(path)
    step, tree = mgr.restore(t1)
    assert step == 10
    np.testing.assert_array_equal(tree["w"], t1["w"])
    # explicit-step restore must NOT silently fall back
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(t1, step=20)


def test_treedef_mismatch_names_first_diverging_path(tmp_path):
    path = str(tmp_path)
    save_checkpoint(path, 3, {"layers": {"attn": np.zeros(2),
                                         "mlp": np.zeros(3)}})
    mgr = CheckpointManager(path)
    with pytest.raises(TreeStructureError) as ei:
        mgr.restore({"layers": {"attn": np.zeros(2),
                                "moe": np.zeros(3)}})
    msg = str(ei.value)
    assert "mlp" in msg and "moe" in msg           # names both sides
    # shape divergence with identical structure is also a caller bug
    with pytest.raises(TreeStructureError) as ei:
        mgr.restore({"layers": {"attn": np.zeros(2), "mlp": np.zeros(7)}})
    assert "mlp" in str(ei.value)


def test_manifest_shape_dtype_audit(tmp_path):
    """A shard whose arrays disagree with the manifest (e.g. stale file
    from a different run) is corrupt, not silently restored."""
    path = str(tmp_path)
    t = _tree(0)
    save_checkpoint(path, 4, t)
    man = os.path.join(path, "step_00000004", "manifest.json")
    with open(man) as f:
        m = json.load(f)
    m["dtypes"][0] = "float64"
    with open(man, "w") as f:
        json.dump(m, f)
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(path, 4, t, verify=False)


# ---------------------------------------------------------------------------
# straggler detection: median must exclude self (the n=2 case)
# ---------------------------------------------------------------------------

def test_straggler_median_excludes_self_two_hosts():
    """With two hosts the SELF-INCLUSIVE median of (fast, slow) sits at
    the slow sample, so the straggler would judge itself normal forever.
    Judging each host against its peers evicts it within `patience`."""
    clock = [0.0]
    mon = HeartbeatMonitor([0, 1],
                           StragglerPolicy(heartbeat_timeout_s=100.0,
                                           straggler_factor=2.0, patience=3),
                           clock=lambda: clock[0])
    failed = []
    for _ in range(4):
        clock[0] += 1.0
        mon.heartbeat(0, 1.0)
        mon.heartbeat(1, 10.0)                     # 10x its peer
        failed += mon.check()
    assert failed == [1]
    assert mon.alive_hosts() == [0]
    # the fast host was never struck: its peer median was the slow sample
    assert mon.hosts[0].slow_strikes == 0


# ---------------------------------------------------------------------------
# train-loop scenarios (real model, small smoke config)
# ---------------------------------------------------------------------------

def test_kill_restart_bit_identical_resume(tmp_path):
    """An uninterrupted 12-step run and a chaos-killed-at-6 + restarted
    run produce IDENTICAL loss trajectories from the restore point on —
    step-indexed data, exact checkpoint restore, and a schedule built
    over the global horizon make the resume bit-exact."""
    from repro.launch.train import run
    full_dir, kill_dir = str(tmp_path / "full"), str(tmp_path / "kill")
    full = run(ARCH, steps=12, ckpt_every=4, ckpt_dir=full_dir, **TRAIN_KW)
    with pytest.raises(ChaosKilled) as ei:
        run(ARCH, steps=12, ckpt_every=4, ckpt_dir=kill_dir,
            chaos=["kill@6"], **TRAIN_KW)
    assert ei.value.code == KILL_EXIT_CODE
    assert latest_step(kill_dir) == 4              # newest committed save
    resumed = run(ARCH, steps=8, ckpt_every=4, ckpt_dir=kill_dir, **TRAIN_KW)
    assert resumed["steps"] == list(range(4, 12))
    assert resumed["losses"] == full["losses"][4:]  # bitwise, not approx


def test_corrupt_checkpoint_restart_falls_back(tmp_path):
    """corrupt@8 damages the step-8 save as it lands; the restart's
    restore detects the CRC mismatch and resumes from step 4."""
    from repro.launch.train import run
    ckpt = str(tmp_path)
    run(ARCH, steps=8, ckpt_every=4, ckpt_dir=ckpt,
        chaos=["corrupt@8"], **TRAIN_KW)
    assert latest_step(ckpt) == 8                  # manifest committed...
    assert verified_steps(ckpt) == [4]             # ...but CRC rejects it
    out = run(ARCH, steps=2, ckpt_every=100, ckpt_dir=ckpt, **TRAIN_KW)
    assert out["steps"][0] == 4                    # fell back past step 8


def test_nan_injection_skips_update_and_stays_finite():
    """nan@3 scales grads by NaN for one step: the in-jit finite guard
    must keep params byte-identical for that step (the next loss equals
    what an update-free step would produce) and the loop records a skip."""
    from repro.launch.train import run
    out = run(ARCH, steps=8, chaos=["nan@3"], **TRAIN_KW)
    assert [e for e in out["events"] if e["kind"] == "skip"] == [
        {"kind": "skip", "step": 3}]
    assert all(np.isfinite(out["losses"]))
    # params were protected: the loss stream never went nonfinite and the
    # post-skip loss continues from the pre-skip params
    assert len(out["losses"]) == 8


def test_silenced_host_evicted_and_loop_remeshes():
    """silence@3:host=1 on a simulated 2-host fleet: the monitor evicts
    the dark host, the loop re-plans the mesh over the survivor and runs
    to completion."""
    from repro.launch.train import run
    out = run(ARCH, steps=10, n_hosts=2, hb_timeout_steps=3.0,
              chaos=["silence@3:host=1"], **TRAIN_KW)
    remesh = [e for e in out["events"] if e["kind"] == "remesh"]
    assert len(remesh) == 1
    assert remesh[0]["failed"] == [1]
    assert remesh[0]["survivors"] == [0]
    assert remesh[0]["plan"]["n_hosts"] == 1
    assert out["steps"][-1] == 9                   # ran to the end
    assert all(np.isfinite(out["losses"]))


# ---------------------------------------------------------------------------
# process-level chaos kinds (real-fleet runtime)
# ---------------------------------------------------------------------------

def test_parse_process_level_chaos_specs():
    sp = parse_chaos("sigkill@9:host=2")
    assert (sp.kind, sp.step, sp.host, sp.duration) == ("sigkill", 9, 2, 1)
    assert parse_chaos("sigkill@9").host == 1       # targets a peer
    sp = parse_chaos("partition@4:host=1,duration=6")
    assert (sp.kind, sp.host, sp.duration) == ("partition", 1, 6)
    assert parse_chaos("partition@4").duration >= 10 ** 6   # dark forever
    assert parse_chaos("diskfull@3").host == 0      # our own writer


def test_rank_targeted_kill():
    """A fleet worker passes its rank and dies only when targeted; the
    single-process simulated fleet (rank=None) dies on any active kill
    because the one real process is every host."""
    chaos = ChaosInjector(["kill@5:host=1"])
    chaos.maybe_kill(5, rank=0)                     # not the target
    assert chaos.fired == []
    with pytest.raises(ChaosKilled):
        chaos.maybe_kill(5, rank=1)
    with pytest.raises(ChaosKilled):
        ChaosInjector(["kill@5:host=1"]).maybe_kill(5)       # rank=None


def test_partition_window_is_rank_and_step_scoped():
    chaos = ChaosInjector(["partition@3:host=2,duration=2"])
    assert not chaos.partitioned(2, 2)
    assert chaos.partitioned(3, 2) and chaos.partitioned(4, 2)
    assert not chaos.partitioned(5, 2)              # window elapsed
    assert not chaos.partitioned(3, 1)              # other rank unaffected


def test_diskfull_hook_raises_enospc_for_target_step_only():
    import errno
    chaos = ChaosInjector(["diskfull@4"])
    chaos.checkpoint_write_hook(3)                  # other steps untouched
    with pytest.raises(OSError) as ei:
        chaos.checkpoint_write_hook(4)
    assert ei.value.errno == errno.ENOSPC
    assert "diskfull@4" in chaos.fired


def test_split_and_supervisor_spec_views():
    from repro.runtime.chaos import split_spec_strings
    sup, wrk = split_spec_strings(["sigkill@7:host=1", "kill@3", "nan@2"])
    assert sup == ["sigkill@7:host=1"] and wrk == ["kill@3", "nan@2"]
    chaos = ChaosInjector(["sigkill@7:host=1", "kill@3"])
    assert [sp.kind for sp in chaos.supervisor_specs()] == ["sigkill"]


def test_diskfull_in_train_loop_costs_recovery_point_not_run(tmp_path):
    """diskfull@4 fails the step-4 async save with ENOSPC: the loop logs
    a ckpt_save_failed event and keeps training; later saves land."""
    from repro.launch.train import run
    ckpt = str(tmp_path)
    out = run(ARCH, steps=8, ckpt_every=2, ckpt_dir=ckpt,
              chaos=["diskfull@4"], **TRAIN_KW)
    fails = [e for e in out["events"] if e["kind"] == "ckpt_save_failed"]
    assert len(fails) == 1 and "disk full" in fails[0]["error"]
    steps = verified_steps(ckpt)
    assert 4 not in steps                           # the failed write
    assert 8 in steps                               # the run went on


# ---------------------------------------------------------------------------
# StragglerPolicy env resolution
# ---------------------------------------------------------------------------

def test_straggler_policy_from_env_precedence(monkeypatch):
    """Resolution order per field: explicit argument > env var > default
    policy baseline."""
    monkeypatch.setenv("REPRO_HEARTBEAT_TIMEOUT", "9.5")
    monkeypatch.setenv("REPRO_STRAGGLER_PATIENCE", "7")
    monkeypatch.delenv("REPRO_STRAGGLER_FACTOR", raising=False)
    base = StragglerPolicy(heartbeat_timeout_s=4.0, straggler_factor=2.5,
                           patience=3)
    p = StragglerPolicy.from_env(default=base)
    assert p.heartbeat_timeout_s == 9.5             # env beats default
    assert p.patience == 7
    assert p.straggler_factor == 2.5                # default fills the gap
    q = StragglerPolicy.from_env(heartbeat_timeout_s=1.25, default=base)
    assert q.heartbeat_timeout_s == 1.25            # explicit beats env
    monkeypatch.setenv("REPRO_STRAGGLER_FACTOR", "")
    assert StragglerPolicy.from_env(default=base).straggler_factor == 2.5


# ---------------------------------------------------------------------------
# checkpoint save/restore races (satellite: concurrency invariants)
# ---------------------------------------------------------------------------

def test_restore_never_picks_uncommitted_step_dir(tmp_path):
    """A save in flight is a step dir without a manifest: newest-step
    discovery must skip it and restore the newest COMMITTED step."""
    path = str(tmp_path)
    t1 = _tree(1)
    save_checkpoint(path, 4, t1)
    newer = os.path.join(path, "step_00000008")     # shard landed, no
    os.makedirs(newer)                              # manifest yet
    np.savez(os.path.join(newer, "shard_0.npz"),
             leaf_0=np.zeros(3, np.float32))
    assert latest_step(path) == 4
    step, tree = CheckpointManager(path).restore(t1)
    assert step == 4
    np.testing.assert_array_equal(tree["w"], t1["w"])


def test_crash_mid_commit_stray_markers_both_directions(tmp_path):
    """Crash between commit files: (a) a stray commit marker for a shard
    the manifest never claims is ignored; (b) a manifest that claims a
    shard whose marker landed but whose data did not fails verification
    and restore falls back."""
    path = str(tmp_path)
    t = _tree(0)
    save_checkpoint(path, 5, t)
    with open(os.path.join(path, "step_00000005", "commit_7.json"),
              "w") as f:
        json.dump({"host_id": 7, "crc32": 0, "n_leaves": 99}, f)
    ok, why = verify_checkpoint(path, 5)
    assert ok, why                                  # (a) stray -> ignored
    save_checkpoint(path, 6, t, n_hosts=2)          # shard 1 never written
    with open(os.path.join(path, "step_00000006", "commit_1.json"),
              "w") as f:
        json.dump({"host_id": 1, "crc32": 123,
                   "n_leaves": len(t)}, f)
    ok, why = verify_checkpoint(path, 6)
    assert not ok and "shard 1 missing" in why      # (b) marker != data
    step, _ = CheckpointManager(path).restore(t)
    assert step == 5


def test_concurrent_save_and_restore_race(tmp_path):
    """A writer committing new steps while a reader restores in a loop:
    the reader must ALWAYS get a fully-committed tree (bit-equal to what
    that step saved) and never crash on a half-written newest dir."""
    import threading
    import time as _time
    path = str(tmp_path)
    trees = {s: _tree(s) for s in range(1, 13)}
    save_checkpoint(path, 1, trees[1])              # reader never starves
    done = threading.Event()
    errors = []

    def writer():
        try:
            for s in range(2, 13):
                save_checkpoint(path, s, trees[s])
                _time.sleep(0.002)
        finally:
            done.set()

    def reader():
        mgr = CheckpointManager(path)
        try:
            while not done.is_set():
                step, tree = mgr.restore(trees[1])
                np.testing.assert_array_equal(tree["w"], trees[step]["w"])
        except Exception as e:  # noqa: BLE001 — surfaced to the test
            errors.append(e)

    tw, tr = threading.Thread(target=writer), threading.Thread(target=reader)
    tw.start(), tr.start()
    tw.join(), tr.join()
    assert not errors, errors
    assert verified_steps(path)[-1] == 12
