"""Fault-tolerant pod runtime: chaos injection, verified checkpoints,
self-healing train loop.

Covers the acceptance scenarios of the fault-tolerance PR, all
deterministic:

  * kill@N -> restart -> BIT-IDENTICAL loss trajectory vs an
    uninterrupted run;
  * corrupt@N -> CRC verification rejects the newest checkpoint and the
    restore falls back to the newest intact older step;
  * nan@N -> the in-jit finite guard skips the update (params untouched)
    and the run stays finite;
  * silence@N:host=H -> heartbeat eviction -> elastic re-mesh -> the loop
    completes over the survivors;
  * checkpoint v2 invariants: multi-host saves don't clobber, treedef
    mismatch raises with the first diverging leaf path, straggler
    detection excludes self from the median (the n=2 case).
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, CheckpointManager,
                              TreeStructureError, latest_step,
                              restore_checkpoint, save_checkpoint,
                              verified_steps, verify_checkpoint)
from repro.runtime import HeartbeatMonitor, StragglerPolicy
from repro.runtime.chaos import (KILL_EXIT_CODE, ChaosInjector, ChaosKilled,
                                 corrupt_checkpoint, parse_chaos)

ARCH = "qwen3-4b"
TRAIN_KW = dict(smoke=True, seq_len=32, global_batch=4, log_every=1000)


# ---------------------------------------------------------------------------
# chaos specs + injector
# ---------------------------------------------------------------------------

def test_parse_chaos_specs():
    sp = parse_chaos("kill@12")
    assert (sp.kind, sp.step, sp.duration) == ("kill", 12, 1)
    sp = parse_chaos("silence@3:host=2,duration=5")
    assert (sp.kind, sp.step, sp.host, sp.duration) == ("silence", 3, 2, 5)
    sp = parse_chaos("slow@4:factor=8.0")
    assert sp.factor == 8.0 and sp.host == 1       # peer by default
    sp = parse_chaos("corrupt@8:mode=truncate")
    assert sp.mode == "truncate" and sp.host == 0  # own shard by default
    assert parse_chaos("nan@5").duration == 1
    for bad in ("kill", "kill@", "boom@3", "kill@3:wat=1", "kill@3:host"):
        with pytest.raises(ValueError):
            parse_chaos(bad)


def test_injector_fault_points_deterministic():
    chaos = ChaosInjector(["nan@3:duration=2", "silence@5:host=1",
                           "slow@2:host=2,factor=4.0,duration=3"])
    assert chaos.grad_scale(2) == 1.0
    assert np.isnan(chaos.grad_scale(3)) and np.isnan(chaos.grad_scale(4))
    assert chaos.grad_scale(5) == 1.0
    assert not chaos.heartbeat_silenced(1, 4)
    assert chaos.heartbeat_silenced(1, 5)
    assert chaos.heartbeat_silenced(1, 10 ** 6)    # silence defaults forever
    assert not chaos.heartbeat_silenced(2, 5)      # wrong host
    assert chaos.step_time_factor(2, 2) == 4.0
    assert chaos.step_time_factor(2, 5) == 1.0     # duration elapsed
    assert chaos.step_time_factor(1, 2) == 1.0
    assert "nan@3" in chaos.fired


def test_injector_kill_is_system_exit_43():
    chaos = ChaosInjector(["kill@7"])
    chaos.maybe_kill(6)                            # not yet
    with pytest.raises(ChaosKilled) as ei:
        chaos.maybe_kill(7)
    assert isinstance(ei.value, SystemExit)
    assert ei.value.code == KILL_EXIT_CODE and ei.value.step == 7


# ---------------------------------------------------------------------------
# checkpoint format v2: shared dir, commit markers, CRC verify, fallback
# ---------------------------------------------------------------------------

def _tree(seed, n=3):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, n)).astype(np.float32),
            "b": rng.normal(size=(n,)).astype(np.float32)}


def test_multi_host_shards_share_one_step_dir(tmp_path):
    """Two hosts saving the same step must not clobber each other (the
    seed's per-host dir rename deleted the other host's shard); host 0's
    manifest is the commit point."""
    path = str(tmp_path)
    t0, t1 = _tree(0), _tree(1)
    save_checkpoint(path, 5, t1, host_id=1, n_hosts=2)
    assert latest_step(path) is None               # no manifest yet
    save_checkpoint(path, 5, t0, host_id=0, n_hosts=2)
    assert latest_step(path) == 5
    step_dir = os.path.join(path, "step_00000005")
    assert sorted(f for f in os.listdir(step_dir)) == [
        "commit_0.json", "commit_1.json", "manifest.json",
        "shard_0.npz", "shard_1.npz"]
    ok, why = verify_checkpoint(path, 5)
    assert ok, why
    r0 = restore_checkpoint(path, 5, t0, host_id=0)
    r1 = restore_checkpoint(path, 5, t0, host_id=1)
    np.testing.assert_array_equal(r0["w"], t0["w"])
    np.testing.assert_array_equal(r1["w"], t1["w"])


def test_verify_detects_missing_pieces(tmp_path):
    path = str(tmp_path)
    save_checkpoint(path, 1, _tree(0), n_hosts=2)  # shard 1 never arrives
    ok, why = verify_checkpoint(path, 1)
    assert not ok and "shard 1" in why
    save_checkpoint(path, 1, _tree(1), host_id=1, n_hosts=2)
    assert verify_checkpoint(path, 1)[0]
    os.remove(os.path.join(path, "step_00000001", "commit_1.json"))
    ok, why = verify_checkpoint(path, 1)
    assert not ok and "never committed" in why


@pytest.mark.parametrize("mode", ["flip", "truncate"])
def test_corrupt_newest_falls_back_to_intact(tmp_path, mode):
    """A damaged newest checkpoint costs one interval, not the run: the
    manager's restore walks back to the newest step that passes CRC."""
    path = str(tmp_path)
    t1, t2 = _tree(1), _tree(2)
    save_checkpoint(path, 10, t1)
    save_checkpoint(path, 20, t2)
    corrupt_checkpoint(path, 20, mode=mode)
    assert verified_steps(path) == [10]
    mgr = CheckpointManager(path)
    step, tree = mgr.restore(t1)
    assert step == 10
    np.testing.assert_array_equal(tree["w"], t1["w"])
    # explicit-step restore must NOT silently fall back
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(t1, step=20)


def test_treedef_mismatch_names_first_diverging_path(tmp_path):
    path = str(tmp_path)
    save_checkpoint(path, 3, {"layers": {"attn": np.zeros(2),
                                         "mlp": np.zeros(3)}})
    mgr = CheckpointManager(path)
    with pytest.raises(TreeStructureError) as ei:
        mgr.restore({"layers": {"attn": np.zeros(2),
                                "moe": np.zeros(3)}})
    msg = str(ei.value)
    assert "mlp" in msg and "moe" in msg           # names both sides
    # shape divergence with identical structure is also a caller bug
    with pytest.raises(TreeStructureError) as ei:
        mgr.restore({"layers": {"attn": np.zeros(2), "mlp": np.zeros(7)}})
    assert "mlp" in str(ei.value)


def test_manifest_shape_dtype_audit(tmp_path):
    """A shard whose arrays disagree with the manifest (e.g. stale file
    from a different run) is corrupt, not silently restored."""
    path = str(tmp_path)
    t = _tree(0)
    save_checkpoint(path, 4, t)
    man = os.path.join(path, "step_00000004", "manifest.json")
    with open(man) as f:
        m = json.load(f)
    m["dtypes"][0] = "float64"
    with open(man, "w") as f:
        json.dump(m, f)
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(path, 4, t, verify=False)


# ---------------------------------------------------------------------------
# straggler detection: median must exclude self (the n=2 case)
# ---------------------------------------------------------------------------

def test_straggler_median_excludes_self_two_hosts():
    """With two hosts the SELF-INCLUSIVE median of (fast, slow) sits at
    the slow sample, so the straggler would judge itself normal forever.
    Judging each host against its peers evicts it within `patience`."""
    clock = [0.0]
    mon = HeartbeatMonitor([0, 1],
                           StragglerPolicy(heartbeat_timeout_s=100.0,
                                           straggler_factor=2.0, patience=3),
                           clock=lambda: clock[0])
    failed = []
    for _ in range(4):
        clock[0] += 1.0
        mon.heartbeat(0, 1.0)
        mon.heartbeat(1, 10.0)                     # 10x its peer
        failed += mon.check()
    assert failed == [1]
    assert mon.alive_hosts() == [0]
    # the fast host was never struck: its peer median was the slow sample
    assert mon.hosts[0].slow_strikes == 0


# ---------------------------------------------------------------------------
# train-loop scenarios (real model, small smoke config)
# ---------------------------------------------------------------------------

def test_kill_restart_bit_identical_resume(tmp_path):
    """An uninterrupted 12-step run and a chaos-killed-at-6 + restarted
    run produce IDENTICAL loss trajectories from the restore point on —
    step-indexed data, exact checkpoint restore, and a schedule built
    over the global horizon make the resume bit-exact."""
    from repro.launch.train import run
    full_dir, kill_dir = str(tmp_path / "full"), str(tmp_path / "kill")
    full = run(ARCH, steps=12, ckpt_every=4, ckpt_dir=full_dir, **TRAIN_KW)
    with pytest.raises(ChaosKilled) as ei:
        run(ARCH, steps=12, ckpt_every=4, ckpt_dir=kill_dir,
            chaos=["kill@6"], **TRAIN_KW)
    assert ei.value.code == KILL_EXIT_CODE
    assert latest_step(kill_dir) == 4              # newest committed save
    resumed = run(ARCH, steps=8, ckpt_every=4, ckpt_dir=kill_dir, **TRAIN_KW)
    assert resumed["steps"] == list(range(4, 12))
    assert resumed["losses"] == full["losses"][4:]  # bitwise, not approx


def test_corrupt_checkpoint_restart_falls_back(tmp_path):
    """corrupt@8 damages the step-8 save as it lands; the restart's
    restore detects the CRC mismatch and resumes from step 4."""
    from repro.launch.train import run
    ckpt = str(tmp_path)
    run(ARCH, steps=8, ckpt_every=4, ckpt_dir=ckpt,
        chaos=["corrupt@8"], **TRAIN_KW)
    assert latest_step(ckpt) == 8                  # manifest committed...
    assert verified_steps(ckpt) == [4]             # ...but CRC rejects it
    out = run(ARCH, steps=2, ckpt_every=100, ckpt_dir=ckpt, **TRAIN_KW)
    assert out["steps"][0] == 4                    # fell back past step 8


def test_nan_injection_skips_update_and_stays_finite():
    """nan@3 scales grads by NaN for one step: the in-jit finite guard
    must keep params byte-identical for that step (the next loss equals
    what an update-free step would produce) and the loop records a skip."""
    from repro.launch.train import run
    out = run(ARCH, steps=8, chaos=["nan@3"], **TRAIN_KW)
    assert [e for e in out["events"] if e["kind"] == "skip"] == [
        {"kind": "skip", "step": 3}]
    assert all(np.isfinite(out["losses"]))
    # params were protected: the loss stream never went nonfinite and the
    # post-skip loss continues from the pre-skip params
    assert len(out["losses"]) == 8


def test_silenced_host_evicted_and_loop_remeshes():
    """silence@3:host=1 on a simulated 2-host fleet: the monitor evicts
    the dark host, the loop re-plans the mesh over the survivor and runs
    to completion."""
    from repro.launch.train import run
    out = run(ARCH, steps=10, n_hosts=2, hb_timeout_steps=3.0,
              chaos=["silence@3:host=1"], **TRAIN_KW)
    remesh = [e for e in out["events"] if e["kind"] == "remesh"]
    assert len(remesh) == 1
    assert remesh[0]["failed"] == [1]
    assert remesh[0]["survivors"] == [0]
    assert remesh[0]["plan"]["n_hosts"] == 1
    assert out["steps"][-1] == 9                   # ran to the end
    assert all(np.isfinite(out["losses"]))
