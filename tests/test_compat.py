"""Unit tests for the JAX portability layer (``repro.runtime.compat``).

Every shim is exercised against whatever JAX is installed — on 0.4.x these
hit the fallback paths, on ≥ 0.6 the native ones — so a rot in either
branch surfaces as a failure here before it takes down the model zoo.
"""
import importlib
import pkgutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import compat


# ---------------------------------------------------------------------------
# Mesh context: set/get round-trip
# ---------------------------------------------------------------------------

def test_mesh_context_round_trip():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        got = compat.get_abstract_mesh()
        assert got is not None and not getattr(got, "empty", False)
        assert tuple(got.axis_names) == ("data", "model")
        assert got.shape["model"] == 1 and got.shape["data"] == 1
    # context exit restores "no ambient mesh"
    after = compat.get_abstract_mesh()
    assert after is None or getattr(after, "empty", False)


def test_mesh_context_nests():
    m1 = compat.make_mesh((1, 1), ("data", "model"))
    m2 = compat.make_mesh((1,), ("model",))
    with compat.set_mesh(m1):
        with compat.set_mesh(m2):
            assert tuple(compat.get_abstract_mesh().axis_names) == ("model",)
        assert tuple(compat.get_abstract_mesh().axis_names) == (
            "data", "model")


def test_sharding_constraint_resolves_under_set_mesh():
    """Bare-PartitionSpec with_sharding_constraint must trace inside the
    compat mesh context on every supported JAX (the 0.4.x resource-env
    fallback is exactly what makes this legal there)."""
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    x = jnp.ones((4, 8))
    with compat.set_mesh(mesh):
        y = jax.jit(lambda x: jax.lax.with_sharding_constraint(
            x, P("data", "model")))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def test_shard_map_psum():
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1,), ("model",))
    fn = compat.shard_map(lambda x: jax.lax.psum(x, "model"), mesh=mesh,
                          in_specs=(P(),), out_specs=P())
    out = fn(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))


# ---------------------------------------------------------------------------
# vma typing: pcast / vma / match_vma
# ---------------------------------------------------------------------------

def test_pcast_identity_outside_shard_map():
    x = jnp.ones((3,))
    y = compat.pcast(x, (), to="varying")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_vma_and_match_vma_degenerate():
    x = jnp.ones((3,))
    assert isinstance(compat.vma(x), frozenset)
    y = compat.match_vma(jnp.zeros((3,)), x)   # same vma -> unchanged value
    np.testing.assert_array_equal(np.asarray(y), np.zeros((3,)))


# ---------------------------------------------------------------------------
# Pallas: element-indexed BlockSpec construction + numerics
# ---------------------------------------------------------------------------

def test_element_block_spec_constructs():
    spec = compat.element_block_spec(
        (compat.Element(8), 16), lambda i, j: (i * 8, j))
    from jax.experimental import pallas as pl
    assert isinstance(spec, pl.BlockSpec)


def test_element_block_spec_halo_numerics():
    """Overlapping (halo) windows via Element dims: out[i] = x[i] + x[i+1],
    computed with a 2-element element-indexed block per grid step."""
    from jax.experimental import pallas as pl
    n = 16
    x = np.arange(n + 1, dtype=np.float32)

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[:-1] + x_ref[1:]

    out = pl.pallas_call(
        kern, grid=(n // 4,),
        in_specs=[compat.element_block_spec(
            (compat.Element(5),), lambda i: (i * 4,))],
        out_specs=pl.BlockSpec((4,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x)
    np.testing.assert_allclose(np.asarray(out), x[:-1] + x[1:])


def test_element_marker_is_int():
    e = compat.Element(8)
    assert isinstance(e, int) and e == 8


# ---------------------------------------------------------------------------
# TPU compiler params
# ---------------------------------------------------------------------------

def test_compiler_params_resolution():
    kw = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    # either the installed Pallas knows the class (kwargs dict ready to
    # splat) or the shim degrades to {} — both must be pallas_call-safe.
    assert isinstance(kw, dict)
    assert set(kw) <= {"compiler_params"}
    if kw:
        assert kw["compiler_params"] is not None


def test_compiler_params_unknown_kwarg_degrades():
    assert compat.tpu_compiler_params(definitely_not_a_real_kwarg=1) == {}


# ---------------------------------------------------------------------------
# Scalar-prefetch grid spec (paged-attention page-table indirection)
# ---------------------------------------------------------------------------

def test_prefetch_scalar_grid_spec_gathers_by_table():
    """Index maps must see the prefetched scalar ref: a 2-page gather
    driven by a page table, in interpret mode."""
    from jax.experimental import pallas as pl

    def kern(pt_ref, x_ref, o_ref):
        o_ref[...] = x_ref[...]

    table = jnp.asarray([2, 0], jnp.int32)
    x = jnp.arange(4 * 8, dtype=jnp.float32).reshape(4, 8)
    spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(2,),
        in_specs=[pl.BlockSpec((1, 8), lambda i, pt_ref: (pt_ref[i], 0))],
        out_specs=pl.BlockSpec((1, 8), lambda i, pt_ref: (i, 0)),
    )
    out = pl.pallas_call(
        kern, grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((2, 8), jnp.float32),
        interpret=True)(table, x)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(x[np.asarray(table)]))


# ---------------------------------------------------------------------------
# cost_analysis normalization
# ---------------------------------------------------------------------------

def test_cost_analysis_returns_flat_dict():
    comp = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    cost = compat.cost_analysis(comp)
    assert isinstance(cost, dict)
    assert cost.get("flops", 0) > 0


# ---------------------------------------------------------------------------
# tree / random aliases
# ---------------------------------------------------------------------------

def test_tree_aliases():
    tree = {"a": jnp.ones((2,)), "b": [jnp.zeros(())]}
    doubled = compat.tree_map(lambda x: x * 2, tree)
    assert float(doubled["a"][0]) == 2.0
    leaves, treedef = compat.tree_flatten(tree)
    assert len(leaves) == len(compat.tree_leaves(tree)) == 2
    rebuilt = compat.tree_unflatten(treedef, leaves)
    assert set(rebuilt) == {"a", "b"}


def test_random_key_feeds_samplers():
    k = compat.random_key(0)
    out = jax.random.normal(k, (3,))
    assert out.shape == (3,)


# ---------------------------------------------------------------------------
# Import sweep: every repro.* module must import cleanly on this JAX
# ---------------------------------------------------------------------------

def _iter_repro_modules():
    import repro
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        yield info.name


@pytest.mark.parametrize("mod", sorted(_iter_repro_modules()))
def test_module_imports_cleanly(mod):
    importlib.import_module(mod)


def test_no_direct_drift_api_call_sites():
    """The grep from the acceptance criteria, as a test: no module outside
    compat.py may touch the version-drifting spellings directly."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
    banned = ("jax.set_mesh", "jax.sharding.get_abstract_mesh",
              "pl.Element(", "jax.lax.pcast", "jax.shard_map(")
    offenders = []
    for path in root.rglob("*.py"):
        if path.name == "compat.py":
            continue
        text = path.read_text()
        offenders += [f"{path.name}: {b}" for b in banned if b in text]
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# Distributed runtime shim
# ---------------------------------------------------------------------------

def test_distributed_initialize_filters_kwargs_to_live_signature(monkeypatch):
    """Keywords the installed ``jax.distributed.initialize`` doesn't take
    are dropped; ``timeout_s`` is mapped onto ``initialization_timeout``
    (an int of seconds) when the signature accepts it."""
    calls = []

    def fake_init(coordinator_address, num_processes, process_id,
                  initialization_timeout=None):
        calls.append(dict(coordinator_address=coordinator_address,
                          num_processes=num_processes,
                          process_id=process_id,
                          initialization_timeout=initialization_timeout))

    monkeypatch.setattr(jax.distributed, "initialize", fake_init)
    ok = compat.distributed_initialize("127.0.0.1:9999", 2, 1,
                                       timeout_s=5.7,
                                       local_device_ids=[0])  # not in sig
    assert ok is True
    assert calls == [dict(coordinator_address="127.0.0.1:9999",
                          num_processes=2, process_id=1,
                          initialization_timeout=5)]


def test_distributed_initialize_passes_extras_through_var_keyword(monkeypatch):
    calls = []

    def fake_init(coordinator_address, num_processes, process_id, **kw):
        calls.append(kw)

    monkeypatch.setattr(jax.distributed, "initialize", fake_init)
    assert compat.distributed_initialize("127.0.0.1:9999", 2, 0,
                                         cluster_detection_method="none")
    assert calls == [{"cluster_detection_method": "none"}]


def test_distributed_initialize_already_up_is_success(monkeypatch):
    def fake_init(**kw):
        raise RuntimeError("Distributed system is already initialized")

    monkeypatch.setattr(jax.distributed, "initialize", fake_init)
    assert compat.distributed_initialize("127.0.0.1:9999", 2, 0) is True


def test_distributed_initialize_degrades_to_warned_false(monkeypatch):
    def fake_init(**kw):
        raise RuntimeError("connection refused")

    monkeypatch.setattr(jax.distributed, "initialize", fake_init)
    with pytest.warns(RuntimeWarning, match="continuing single-process"):
        assert compat.distributed_initialize("127.0.0.1:9", 2, 0) is False


def test_distributed_shutdown_never_raises(monkeypatch):
    def boom():
        raise RuntimeError("not initialized")

    monkeypatch.setattr(jax.distributed, "shutdown", boom)
    compat.distributed_shutdown()  # must swallow
