"""Quickstart: the paper's scheduling methodology end-to-end on one GEMM.

1. Formulate C = A @ B as an NDRange tensor op (paper Eq. 1).
2. Search the bandwidth-minimizing TEU tile (Eq. 4).
3. Plan FIFO-mesh data exchange on a 4x4 TEU mesh (Fig. 2).
4. Lower the same schedule to a Pallas TPU kernel and validate vs jnp.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TEU_BUFFER, matmul_op, plan_mesh_exchange,
                        order_grid_for_sharing, search_tiles)
from repro.core.pallas_bridge import matmul_block_shapes
from repro.kernels import ops, ref

# 1. NDRange form
op = matmul_op(1024, 1024, 1024)
print(f"workload: {op.name}, {op.total_macs()/1e6:.0f} MMACs")

# 2. TEU tile (paper hardware: 2x16KB inputs, 5KB PSums, 32 PEs)
sched = search_tiles(op, TEU_BUFFER)
print(f"TEU tile: {sched.tile}  -> {sched.bytes_per_mac:.4f} bytes/MAC")

# 3. FIFO-mesh exchange on a 4x4 mesh of TEUs
plan = plan_mesh_exchange(op, sched.tile, (4, 4))
print(f"exchange: share A along '{plan.row_axis}', B along "
      f"'{plan.col_axis}' -> {plan.sharing_factor:.1f}x fewer GLB fetches, "
      f"{plan.fifo_hop_bytes/1e6:.1f} MB over FIFOs instead")

# 4. The same schedule on TPU: MXU-aligned blocks + VMEM residency order
order = order_grid_for_sharing(op, sched.tile)
print(f"grid order (VMEM residency): {order.order}")
bm, bn, bk = matmul_block_shapes(1024, 1024, 1024)
print(f"Pallas blocks (VMEM-budget tile search): ({bm}, {bn}, {bk})")

a = jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)), jnp.float32)
b = jnp.asarray(np.random.default_rng(1).normal(size=(256, 256)), jnp.float32)
out = ops.matmul(a, b, block_m=64, block_n=64, block_k=64)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(a, b)),
                           rtol=1e-4, atol=1e-4)
print("Pallas kernel (interpret mode) matches the jnp oracle — done.")
