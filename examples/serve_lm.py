"""Batched serving example: continuous batching over a small model.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 8 --slots 4
"""
import argparse

from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--kv-mode", default="dense",
                    choices=("dense", "paged", "paged_int8"))
    a = ap.parse_args()
    results = run(a.arch, smoke=True, n_requests=a.requests, slots=a.slots,
                  max_new=a.max_new, prompt_len=10, max_len=48,
                  kv_mode=a.kv_mode)
    for rid, toks in sorted(results.items()):
        print(f"request {rid}: generated {toks}")


if __name__ == "__main__":
    main()
