"""End-to-end training driver: a ~100M-param qwen3-family model for a few
hundred steps on the synthetic pipeline, with checkpoints + restart.

The CPU container defaults keep this runnable in minutes (--layers 4
--d-model 256 ...). On a real pod, pass --mesh single and the full config;
everything else (shardings, checkpointing, data) is identical.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax.numpy as jnp

from repro.configs.base import ArchBundle
from repro.models import TransformerConfig, transformer
from repro.launch.train import run


def bundle_100m(layers, d_model, heads, kv, d_ff, vocab):
    cfg = TransformerConfig(
        name="train-lm-100m", n_layers=layers, d_model=d_model,
        n_heads=heads, n_kv_heads=kv, d_ff=d_ff, vocab=vocab, qk_norm=True,
        dtype=jnp.float32)
    return ArchBundle("train-lm-100m", "dense", cfg, transformer)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    a = ap.parse_args()

    import repro.configs as C
    b = bundle_100m(a.layers, a.d_model, max(4, a.d_model // 64),
                    max(2, a.d_model // 128), a.d_model * 4, 8192)
    print(f"model: {b.param_count()/1e6:.1f}M params")
    C.REGISTRY["train-lm-100m"] = type(
        "M", (), {"ARCH_ID": "train-lm-100m",
                  "full_bundle": staticmethod(lambda: b),
                  "smoke_bundle": staticmethod(lambda: b)})
    out = run("train-lm-100m", smoke=True, steps=a.steps, seq_len=a.seq_len,
              global_batch=a.global_batch, ckpt_dir=a.ckpt_dir,
              ckpt_every=50, lr=1e-3, log_every=10)
    losses = out["losses"]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    assert losses[-1] < losses[0], "training failed to reduce loss"


if __name__ == "__main__":
    main()
