"""Reproduce the paper's evaluation: Table III + Fig. 3/4 numbers.

Run:  PYTHONPATH=src python examples/vectormesh_sim.py
"""
from repro.sim import (CLASSIC, MODERN, SPATIAL, eyeriss, simulate, summarize,
                       tpu, vectormesh)


def main():
    print("=== Table III (normalized access = bytes / 1000 MACs) ===")
    print(f"{'arch':18s} {'GLB':>8s} {'DRAM':>8s} {'GMAC/s':>8s} "
          f"{'rf':>5s}")
    for n_pe in (128, 512):
        for name, mk in (("tpu", tpu), ("eyeriss", eyeriss),
                         ("vectormesh", vectormesh)):
            s = summarize([simulate(mk(n_pe), w) for w in CLASSIC])
            print(f"{name+'-'+str(n_pe):18s} {s['norm_glb']:8.1f} "
                  f"{s['norm_dram']:8.1f} {s['gmacs']:8.1f} "
                  f"{s['roofline_frac']:5.2f}")

    print("\n=== Fig. 4: VectorMesh-exclusive workloads (512 PE) ===")
    for w in MODERN + SPATIAL:
        r = simulate(vectormesh(512), w)
        print(f"{w.name:16s} {r.gmacs:7.2f} / {r.roofline_gmacs:7.2f} GMAC/s "
              f"({r.roofline_frac:.2f} of roofline)")


if __name__ == "__main__":
    main()
